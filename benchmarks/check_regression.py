"""Bench-regression gate: fresh BENCH_*.json vs the committed copies.

  PYTHONPATH=src python -m benchmarks.check_regression --fresh bench_out

CI emits fresh trajectory artifacts into a scratch directory
(``benchmarks.run --smoke --out-dir bench_out``) and this gate compares
them against the committed repo-root copies.  Only STRUCTURAL metrics are
gated — quantities that are deterministic functions of the code, not of
the shared runner's wall clock:

  overlap  HLO shape of the streamed plane: ppermute count, monolithic
           all-gathers eliminated, HLO-vs-analytic byte parity, oracle
           identity (max_abs_err == 0), the predicted speedups of the
           plan model (pure arithmetic -> tight tolerance), the
           bidirectional ring's per-direction permute split and halved
           hop depth, and the dynamic-correction contention verdicts
           (zero steals undisturbed, bounded steals + spread convergence
           under the injected slowdown).
  plan     hierarchical-vs-flat predicted finish speedup, DCN volume
           reduction, pod shares (all solver outputs, deterministic).
  serve    workload-shape invariants (useful tokens, paged token
           identity, fragmentation evidence) and occupancy, which is a
           deterministic function of the schedule; the prefix-sharing
           smoke (token identity vs the private plane and the greedy
           oracle, peak pages-in-use strictly below the private
           baseline, refcounted attaches, conservation at drain).
           tok/s and TTFT are NOT gated: shared CI runners swing
           several-fold.

Wall-clock metrics are reported but never fail the gate.  Exit code 1 on
any regression, with a per-check report.  When a tracked artifact is
missing on either side the gate fails: silently skipping a comparison is
how regressions sneak in.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Any, Callable, List, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
ARTIFACTS = ("BENCH_plan.json", "BENCH_serve.json", "BENCH_overlap.json")


def dig(tree: Any, path: str) -> Any:
    node = tree
    for part in path.split("."):
        node = node[part]
    return node


class Gate:
    def __init__(self):
        self.failures: List[str] = []
        self.passed: List[str] = []

    def check(self, label: str, ok: bool, detail: str = "") -> None:
        if ok:
            self.passed.append(label)
        else:
            self.failures.append(f"{label}  {detail}")

    def equal(self, label: str, fresh: Any, base: Any) -> None:
        self.check(label, fresh == base, f"fresh={fresh!r} base={base!r}")

    def close(self, label: str, fresh: float, base: float,
              rel: float) -> None:
        """fresh within rel of base (two-sided: a 'too good' jump is a
        broken metric until the committed artifact is refreshed)."""
        denom = max(abs(base), 1e-12)
        drift = abs(fresh - base) / denom
        self.check(label, drift <= rel,
                   f"fresh={fresh:.6g} base={base:.6g} "
                   f"drift={drift:.2%} > {rel:.0%}")

    def at_least(self, label: str, fresh: float, floor: float) -> None:
        self.check(label, fresh >= floor, f"fresh={fresh:.6g} < {floor}")


def check_overlap(g: Gate, fresh: dict, base: dict) -> None:
    # HLO structure of the streamed plane — exact
    g.equal("overlap: model-ring ppermute count",
            dig(fresh, "structure.model_ring.ppermutes"),
            dig(base, "structure.model_ring.ppermutes"))
    g.equal("overlap: zero monolithic all-gathers",
            dig(fresh, "structure.allgather_free"), True)
    # byte parity: the lowered HLO moves EXACTLY the registry's bytes
    g.equal("overlap: HLO-vs-analytic byte parity",
            dig(fresh, "structure.model_ring.link_bytes_hlo"),
            dig(fresh, "structure.model_ring.link_bytes_analytic"))
    # the accumulate-and-forward ring reduces in a different order than
    # the blocking psum_scatter — bit-identity is backend luck, so gate
    # on the benchmark's own tolerance, not on 0.0
    g.check("overlap: streamed == blocking oracle (max_abs_err)",
            dig(fresh, "identity.max_abs_err") <= 1e-4,
            f"max_abs_err={dig(fresh, 'identity.max_abs_err')!r} > 1e-4")
    # plan-model predictions are pure arithmetic on fixed constants
    g.close("overlap: predicted plan speedup",
            dig(fresh, "prediction.predicted_overlap_speedup"),
            dig(base, "prediction.predicted_overlap_speedup"), 0.02)
    g.close("overlap: roofline collective-bound speedup",
            dig(fresh, "prediction.roofline_split.overlap_speedup"),
            dig(base, "prediction.roofline_split.overlap_speedup"), 0.02)
    # bidirectional half-rings: same op count/bytes as the unidirectional
    # ring, permutes split ceil((p-1)/2)/floor((p-1)/2) per direction
    br = dig(fresh, "structure.bidir_ring")
    p = br["p"]
    g.equal("overlap: bidir ppermute count unchanged",
            br["ppermutes"], dig(fresh, "structure.model_ring.ppermutes"))
    g.equal("overlap: bidir per-direction split",
            (br["forward"], br["backward"]),
            (-(-(p - 1) // 2), (p - 1) // 2))
    g.equal("overlap: bidir byte parity with registry",
            br["link_bytes_hlo"],
            dig(fresh, "structure.model_ring.link_bytes_analytic"))
    g.check("overlap: bidir halves the sequential hop depth",
            br["hop_depth"] == -(-(p - 1) // 2)
            and br["hop_depth"] < br["hop_depth_unidir"],
            f"depth={br['hop_depth']} unidir={br['hop_depth_unidir']}")
    # dynamic correction: the contention scenario's own booleans (spread
    # vs tolerance is computed in the bench process — the committed JSON
    # only carries the verdicts, so rounding can't flip a gate here)
    for plane in ("train", "overlap"):
        gates = dig(fresh, f"contention.{plane}.gates")
        for key in ("steals_undisturbed_zero", "plan_identical_undisturbed",
                    "steals_bounded", "spread_converged",
                    "makespan_improved"):
            g.equal(f"overlap: contention[{plane}] {key}", gates[key], True)


def check_plan(g: Gate, fresh: dict, base: dict) -> None:
    g.close("plan: hierarchical finish speedup",
            dig(fresh, "finish_speedup"), dig(base, "finish_speedup"), 0.02)
    g.close("plan: DCN distribution-volume reduction",
            dig(fresh, "dcn_reduction"), dig(base, "dcn_reduction"), 0.02)
    g.equal("plan: pod shares (solver determinism)",
            dig(fresh, "hierarchical.pod_shares"),
            dig(base, "hierarchical.pod_shares"))
    g.equal("plan: trunk aggregation bytes",
            dig(fresh, "aggregation_dcn_per_pod.hierarchical_bytes"),
            dig(base, "aggregation_dcn_per_pod.hierarchical_bytes"))


def check_serve(g: Gate, fresh: dict, base: dict) -> None:
    # same committed workload -> identical useful-token count
    g.equal("serve: engine useful tokens",
            dig(fresh, "engine.useful_tokens"),
            dig(base, "engine.useful_tokens"))
    g.equal("serve: paged plane token-identical to slot plane",
            dig(fresh, "paged_vs_slot.token_identical"), True)
    # fragmentation evidence: the paged comparison must actually exercise
    # multi-page non-contiguous requests, or it proves nothing
    g.at_least("serve: paged multi-page requests",
               dig(fresh, "paged_vs_slot.multi_page_requests"),
               dig(base, "paged_vs_slot.multi_page_requests"))
    g.at_least("serve: paged fragmented requests",
               dig(fresh, "paged_vs_slot.fragmented_requests"), 1)
    # occupancy is schedule-determined, not wall-clock-determined
    g.close("serve: engine occupancy",
            dig(fresh, "engine.occupancy"),
            dig(base, "engine.occupancy"), 0.05)
    g.close("serve: paged page occupancy",
            dig(fresh, "paged.page_occupancy"),
            dig(base, "paged.page_occupancy"), 0.05)
    # fleet rescale scenario: tick-driven and fault-scheduled, so every
    # number below is a deterministic function of the code
    g.equal("serve: fleet token-identical under kill/join",
            dig(fresh, "fleet.token_identical"), True)
    g.equal("serve: fleet completed everything",
            dig(fresh, "fleet.completed"),
            dig(fresh, "workload.requests"))
    g.at_least("serve: fleet kill actually requeued work",
               dig(fresh, "fleet.requeued"), 1)
    g.equal("serve: fleet kill/join schedule ran",
            (dig(fresh, "fleet.kills"), dig(fresh, "fleet.joins")),
            (dig(base, "fleet.kills"), dig(base, "fleet.joins")))
    # metrics-plane structural gates: the observability counters must
    # agree with the fleet report (requeues) and the admission plane must
    # have counted the exercised rejection — both tick-deterministic
    g.equal("serve: metrics requeue counter matches fleet report",
            dig(fresh, "fleet.metrics.requeues"),
            dig(fresh, "fleet.requeued"))
    g.equal("serve: fleet requeue count vs baseline",
            dig(fresh, "fleet.metrics.requeues"),
            dig(base, "fleet.metrics.requeues"))
    g.at_least("serve: admission rejections counted",
               dig(fresh, "fleet.metrics.admission_rejections"), 1)
    g.equal("serve: admission-rejection count vs baseline",
            dig(fresh, "fleet.metrics.admission_rejections"),
            dig(base, "fleet.metrics.admission_rejections"))
    # work stealing is enabled in the fleet scenario but the injected
    # faults are kill/join, not contention: the corrector's hysteresis
    # must hold at zero steals on this schedule
    g.equal("serve: fleet steals zero on uncontended schedule",
            dig(fresh, "fleet.steals"), 0)
    g.equal("serve: steal counter agrees with fleet report",
            dig(fresh, "fleet.metrics.steals"),
            dig(fresh, "fleet.steals"))
    # chaos smoke: the composite fault schedule's structural verdicts.
    # Every transient scheduled to clear must have recovered through
    # retry/backoff, every rescale must have restored the checkpointed
    # state (falling back past the torn snapshots, which must have been
    # DETECTED, not loaded), tokens must equal the single-engine
    # reference, and nothing may be silently dropped.
    ch = dig(fresh, "fleet.chaos")
    for key in ("recovered_all_transients", "restores_match_rescales",
                "token_identical", "zero_silent_drops"):
        g.equal(f"serve: chaos gate {key}", ch["gates"][key], True)
    g.equal("serve: chaos recoveries == injected transients",
            ch["recoveries"], ch["transients_injected"])
    g.equal("serve: chaos restores == rescales (kills + joins)",
            ch["restores"], ch["kills"] + ch["joins"])
    g.equal("serve: chaos completed everything",
            ch["completed"], dig(fresh, "workload.requests"))
    g.at_least("serve: chaos torn snapshots detected", ch["corrupt_shards"],
               1)
    g.at_least("serve: chaos retry path exercised", ch["retries"], 1)
    g.equal("serve: chaos fault schedule vs baseline",
            (ch["kills"], ch["joins"], ch["retries"], ch["recoveries"],
             ch["restores"], ch["corrupt_shards"], ch["requeues"]),
            tuple(dig(base, "fleet.chaos")[k] for k in
                  ("kills", "joins", "retries", "recoveries", "restores",
                   "corrupt_shards", "requeues")))
    g.equal("serve: chaos metrics counters agree with report",
            (ch["metrics"]["retries"], ch["metrics"]["recoveries"],
             ch["metrics"]["restores"]),
            (ch["retries"], ch["recoveries"], ch["restores"]))
    # prefix sharing: the shared-template capacity smoke is fully
    # deterministic (seeded workload, tick clock), so identity, the
    # attach evidence and the pages-in-use win are all structural
    ps = dig(fresh, "prefix_sharing")
    g.equal("serve: sharing token-identical to private plane",
            ps["token_identical_vs_private"], True)
    g.equal("serve: sharing token-identical to greedy oracle",
            ps["token_identical_vs_oracle"], True)
    g.check("serve: sharing peak pages strictly below private baseline",
            ps["peak_used_pages_shared"] < ps["peak_used_pages_private"],
            f"shared={ps['peak_used_pages_shared']} "
            f"private={ps['peak_used_pages_private']}")
    g.check("serve: sharing capacity ratio > 1",
            ps["capacity_ratio"] > 1.0,
            f"ratio={ps['capacity_ratio']:.3f}")
    g.at_least("serve: sharing attaches observed", ps["shared_attaches"], 1)
    g.at_least("serve: sharing refcount actually exceeded 1",
               ps["max_refcount"], 2)
    g.equal("serve: sharing refcount conservation at drain",
            ps["refcount_conserved"], True)
    g.equal("serve: sharing evidence vs baseline",
            (ps["peak_used_pages_private"], ps["peak_used_pages_shared"],
             ps["shared_attaches"], ps["max_refcount"]),
            tuple(dig(base, "prefix_sharing")[k] for k in
                  ("peak_used_pages_private", "peak_used_pages_shared",
                   "shared_attaches", "max_refcount")))


CHECKS: Tuple[Tuple[str, Callable[[Gate, dict, dict], None]], ...] = (
    ("BENCH_overlap.json", check_overlap),
    ("BENCH_plan.json", check_plan),
    ("BENCH_serve.json", check_serve),
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory holding the freshly-emitted "
                         "BENCH_*.json artifacts")
    ap.add_argument("--baseline", default=str(REPO_ROOT),
                    help="directory holding the committed baselines "
                         "(default: repo root)")
    args = ap.parse_args(argv)
    fresh_dir = pathlib.Path(args.fresh)
    base_dir = pathlib.Path(args.baseline)

    g = Gate()
    for name, fn in CHECKS:
        fpath, bpath = fresh_dir / name, base_dir / name
        if not fpath.exists() or not bpath.exists():
            g.check(f"{name}: artifact present on both sides", False,
                    f"fresh={fpath.exists()} baseline={bpath.exists()}")
            continue
        try:
            fn(g, json.loads(fpath.read_text()),
               json.loads(bpath.read_text()))
        except KeyError as e:
            g.check(f"{name}: schema", False, f"missing key {e}")

    for label in g.passed:
        print(f"  ok  {label}")
    for line in g.failures:
        print(f"FAIL  {line}")
    n = len(g.passed) + len(g.failures)
    if g.failures:
        print(f"\nbench-regression gate: {len(g.failures)}/{n} checks "
              f"FAILED (structural metrics regressed — or the committed "
              f"BENCH_*.json baselines need a refresh in this PR)")
        return 1
    print(f"\nbench-regression gate: all {n} checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
