"""Benchmark driver: one section per paper figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--skip-roofline] [--skip-serve]
  PYTHONPATH=src python -m benchmarks.run --smoke [--out-dir DIR]

Prints human-readable sections followed by ``name,value,note`` CSV rows
(the machine-readable summary used by EXPERIMENTS.md).  The trajectory
artifacts — ``BENCH_plan.json`` / ``BENCH_serve.json`` /
``BENCH_overlap.json`` — are written to the REPOSITORY ROOT (same
filenames CI emits), so perf is tracked across PRs.

``--smoke`` is the consolidated CI entry point: it runs ONLY the three
trajectory benchmarks (plan / overlap / serve) and writes their JSON
artifacts into ``--out-dir`` (default: the repo root).  CI points
``--out-dir`` at a scratch directory so ``benchmarks.check_regression``
can diff the fresh artifacts against the committed repo-root copies.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_trajectory(out_dir: pathlib.Path, rows, out,
                   skip_serve: bool = False) -> bool:
    """The three trajectory benchmarks -> out_dir/BENCH_*.json.
    Returns False if any section failed."""
    out_dir.mkdir(parents=True, exist_ok=True)
    ok = True

    # planning subsystem: flat star vs two-level hierarchy on the
    # production multi-pod shape
    t0 = time.time()
    from . import plan as plan_bench
    pr = plan_bench.main(["--smoke",
                          "--out", str(out_dir / "BENCH_plan.json")])
    rows.append(("plan.hier_finish_speedup_x", pr["finish_speedup"],
                 "flat star priced on the true shared trunks"))
    rows.append(("plan.hier_dcn_reduction_pct", pr["dcn_reduction"] * 100,
                 "distribution volume on DCN trunks"))
    out(f"[plan benchmarks {time.time()-t0:.1f}s]")

    # overlapped layer-streaming plane: needs 8 host devices, so it runs
    # as a subprocess (this process keeps the real device topology)
    t0 = time.time()
    from ._util import host_device_env
    env = host_device_env(8)
    env.setdefault("PYTHONPATH", str(REPO_ROOT / "src"))
    overlap_out = out_dir / "BENCH_overlap.json"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.overlap", "--smoke",
         "--out", str(overlap_out)],
        env=env, cwd=str(REPO_ROOT), capture_output=True, text=True)
    if r.returncode == 0:
        ov = json.loads(overlap_out.read_text())
        rows.append(("overlap.predicted_speedup_x",
                     ov["prediction"]["predicted_overlap_speedup"],
                     "serial vs max(comm, compute) on 2x16x16"))
        rows.append(("overlap.roofline_speedup_x",
                     ov["prediction"]["roofline_split"]["overlap_speedup"],
                     "serial vs overlapped collective bound"))
    else:
        ok = False
        out(f"[overlap benchmark FAILED]\n{r.stdout}\n{r.stderr}")
    out(f"[overlap benchmarks {time.time()-t0:.1f}s]")

    # serving engine vs fixed batches + paged-vs-slot comparison
    if not skip_serve:
        t0 = time.time()
        from . import serve as serve_bench
        sr = serve_bench.main(["--smoke",
                               "--out", str(out_dir / "BENCH_serve.json")])
        rows.append(("serve.engine_speedup_x", sr["speedup"],
                     "continuous batching vs fixed batches (smoke)"))
        rows.append(("serve.paged_vs_slot_x",
                     sr["paged_vs_slot"]["tokens_per_sec_ratio"],
                     "paged KV plane vs slot plane tok/s"))
        rows.append(("serve.fleet_token_identical",
                     float(sr["fleet"]["token_identical"]),
                     "3-replica fleet == single engine under kill/join"))
        rows.append(("serve.fleet_requeued", float(sr["fleet"]["requeued"]),
                     "requests requeued by the mid-decode kill"))
        rows.append(("serve.sharing_capacity_ratio_x",
                     sr["prefix_sharing"]["capacity_ratio"],
                     "peak pages private reservation vs prefix sharing"))
        rows.append(("serve.sharing_token_identical",
                     float(sr["prefix_sharing"]["token_identical_vs_private"]
                           and sr["prefix_sharing"]
                           ["token_identical_vs_oracle"]),
                     "sharing == private plane == greedy oracle"))
        out(f"[serve benchmarks {time.time()-t0:.1f}s]")

        # the shared-prefix example doubles as an end-to-end smoke: it
        # asserts oracle identity + the capacity win on its own workload
        t0 = time.time()
        env = host_device_env(1)
        env.setdefault("PYTHONPATH", str(REPO_ROOT / "src"))
        r = subprocess.run(
            [sys.executable, str(REPO_ROOT / "examples"
                                 / "prefix_sharing.py")],
            env=env, cwd=str(REPO_ROOT), capture_output=True, text=True)
        if r.returncode != 0:
            ok = False
            out(f"[prefix-sharing example FAILED]\n{r.stdout}\n{r.stderr}")
        out(f"[prefix-sharing example {time.time()-t0:.1f}s]")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-serve", action="store_true",
                    help="skip the (slow) serving-engine smoke")
    ap.add_argument("--smoke", action="store_true",
                    help="trajectory benchmarks only (the consolidated "
                         "CI step); honors --out-dir")
    ap.add_argument("--out-dir", default=str(REPO_ROOT),
                    help="where BENCH_*.json artifacts land")
    args = ap.parse_args()

    rows = []

    def out(msg=""):
        print(msg, flush=True)

    out_dir = pathlib.Path(args.out_dir)

    if args.smoke:
        ok = run_trajectory(out_dir, rows, out,
                            skip_serve=args.skip_serve)
        out("\n=== name,value,note CSV ===")
        out("name,value,note")
        for name, val, note in rows:
            out(f"{name},{val:.4f},{note}")
        if not ok:
            sys.exit(1)
        return

    t0 = time.time()
    from . import star
    rows += [("bench", "fig6", "star 16-child")] and star.report(out)
    out(f"[star benchmarks {time.time()-t0:.1f}s]")

    t0 = time.time()
    from . import mesh
    rows += mesh.report(out)
    out(f"[mesh benchmarks {time.time()-t0:.1f}s]")

    ok = run_trajectory(out_dir, rows, out, skip_serve=args.skip_serve)

    # scheduler-plane wall time (the runtime re-solves these on rebalance)
    import numpy as _np
    from repro.core.network import random_mesh, random_star
    from repro.core.star import solve as star_solve
    from repro.core.integer_adjust import solve_integer
    from repro.core.heuristic import mft_lbp_heuristic
    net = random_star(16, seed=0)
    m5 = random_mesh(5, 5, seed=0)
    for name, fn, reps in [
        ("star_pccs_solve", lambda: star_solve(net, 1000, "PCCS"), 200),
        ("star_integer_adjust", lambda: solve_integer(net, 1000, "PCCS"), 50),
        ("mesh_heuristic_5x5", lambda: mft_lbp_heuristic(m5, 1000), 5),
    ]:
        t = time.time()
        for _ in range(reps):
            fn()
        us = (time.time() - t) / reps * 1e6
        rows.append((f"sched.{name}_us", us, "solver wall time per call"))

    if not args.skip_roofline:
        from . import roofline_report
        rows += roofline_report.report(out)

    out("\n=== name,value,note CSV ===")
    out("name,value,note")
    for name, val, note in rows:
        out(f"{name},{val:.4f},{note}")
    if not ok:   # a trajectory section failed: exit red, not green
        sys.exit(1)


if __name__ == "__main__":
    main()
