"""Overlapped layer-streaming plane vs blocking collectives.

  PYTHONPATH=src python -m benchmarks.overlap [--smoke] [--contention]
                                              [--out BENCH_overlap.json]
  (re-executes itself with 8 host devices)

Four sections, emitted to ``BENCH_overlap.json`` (CI runs ``--smoke``):

  structure   the lowered overlapped ``lbp_row_parallel`` contains ZERO
              monolithic all-gathers and exactly p-1 collective-permutes
              whose link bytes equal the ``core.collectives`` registry's
              analytic table for the stream_* modes (verified via
              ``analysis.hlo_collectives.collective_summary``); the
              bidirectional flavour additionally splits them
              ceil((p-1)/2) forward / floor((p-1)/2) backward at
              identical bytes (``permute_direction_counts``).
  identity    streamed outputs == blocking outputs on the miniature
              (pod=2, data=2, model=2) production mesh; wall time of both
              planes (best-of-reps; CPU hosts have no async collectives,
              so this is a dispatch-cost check, not the TPU win).
  prediction  the §4 "overlap" objective vs serial PCCS on the production
              2x16x16 shape — finish governed by max(comm, compute)
              rather than the sum — plus the ICI-vs-DCN roofline split of
              the aggregation bytes (``serial_vs_overlap``).
  contention  the dynamic-correction scenario: a mid-run 2x slowdown on
              the biggest-share node of the canonical 8-node star, serial
              and overlap planes, static plan vs drift-triggered work
              stealing (``--contention`` runs just this section).
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_OUT = str(REPO_ROOT / "BENCH_overlap.json")

if __name__ == "__main__":
    import sys
    sys.path.insert(0, str(REPO_ROOT))
    from benchmarks._util import ensure_host_devices, time_best
    ensure_host_devices(8)
else:
    from ._util import ensure_host_devices, time_best  # noqa: F401


def _structure_section(n_dev: int) -> Dict:
    """HLO of the overlapped plane: no all-gather, p-1 ppermutes, exact
    byte match with the registry."""
    import jax
    from repro.analysis.hlo_collectives import (collective_summary,
                                                permute_direction_counts)
    from repro.compat import make_mesh
    from repro.core import collectives, overlap
    from repro.models import lbp_linear
    from repro.models.tuning import set_tuning
    from repro.sharding.rules import Rules

    B, S, K, d = 2, 16, 64, 32
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, d))

    # pure model-axis ring: the stream_scatter aggregation alone
    mesh = make_mesh((n_dev,), ("model",))
    rules = Rules(seq="model", ff="model", mesh=mesh)
    set_tuning(explicit_lbp_scatter=True, overlap_streaming=True)
    comp = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules)
                   ).lower(h, w).compile()
    summ = collective_summary(comp.as_text(), n_dev)
    per_op = summ["per_op"]
    assert "all-gather" not in per_op, per_op
    assert "reduce-scatter" not in per_op and "all-reduce" not in per_op, per_op
    pp = per_op["collective-permute"]
    analytic = collectives.collective_bytes_per_device(
        B * S * d, n_dev, "stream_scatter", itemsize=4)
    expect_n = overlap.expected_ppermutes("stream_scatter", n_dev)
    assert pp["count"] == expect_n, (pp, expect_n)
    assert abs(pp["link_bytes"] - analytic) < 1e-6, (pp, analytic)

    # bidirectional half-rings: same op count and bytes, permutes split
    # ceil((p-1)/2) forward / floor((p-1)/2) backward — the structural
    # signature of the halved sequential hop depth
    set_tuning(overlap_bidir=True)
    compb = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules)
                    ).lower(h, w).compile()
    hlob = compb.as_text()
    summb = collective_summary(hlob, n_dev)
    per_opb = summb["per_op"]
    assert "all-gather" not in per_opb, per_opb
    assert "reduce-scatter" not in per_opb and "all-reduce" not in per_opb
    ppb = per_opb["collective-permute"]
    assert ppb["count"] == overlap.expected_ppermutes(
        "stream_scatter_bidir", n_dev)
    assert abs(ppb["link_bytes"] - analytic) < 1e-6, (ppb, analytic)
    dirs = permute_direction_counts(hlob, n_dev)
    hf, hb = overlap.expected_direction_counts("stream_scatter_bidir", n_dev)
    assert (dirs["forward"], dirs["backward"]) == (hf, hb), (dirs, hf, hb)
    assert dirs["other"] == 0, dirs
    set_tuning(overlap_bidir=False)

    # full (pod, data, model) mesh: the FSDP weight ring joins in and the
    # module still lowers with zero monolithic all-gathers
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules3 = Rules(batch=("pod", "data"), seq="model", embed="data",
                   ff="model", mesh=mesh3)
    h3 = jax.random.normal(jax.random.PRNGKey(2), (4, 8, K))
    comp3 = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules3)
                    ).lower(h3, w).compile()
    summ3 = collective_summary(comp3.as_text(), n_dev)
    assert "all-gather" not in summ3["per_op"], summ3["per_op"]
    set_tuning(overlap_streaming=False)
    return {
        "model_ring": {"p": n_dev, "ppermutes": pp["count"],
                       "link_bytes_hlo": pp["link_bytes"],
                       "link_bytes_analytic": analytic},
        "bidir_ring": {
            "p": n_dev, "ppermutes": ppb["count"],
            "link_bytes_hlo": ppb["link_bytes"],
            "forward": dirs["forward"], "backward": dirs["backward"],
            "hop_depth": overlap.sequential_hop_depth(
                "stream_scatter_bidir", n_dev),
            "hop_depth_unidir": overlap.sequential_hop_depth(
                "stream_scatter", n_dev),
        },
        "pod_mesh": {"per_op": summ3["per_op"]},
        "allgather_free": True,
    }


def _identity_section(reps: int) -> Dict:
    """Streamed == blocking on the miniature production mesh + wall time."""
    import jax
    import numpy as np
    from repro.compat import make_mesh
    from repro.models import lbp_linear
    from repro.models.tuning import set_tuning
    from repro.sharding.rules import Rules

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = Rules(batch=("pod", "data"), seq="model", embed="data",
                  ff="model", mesh=mesh)
    B, S, K, d = 4, 32, 256, 128
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, d))
    set_tuning(explicit_lbp_scatter=True)

    outs, walls = {}, {}
    for name, streaming in (("blocking", False), ("streamed", True)):
        set_tuning(overlap_streaming=streaming)
        fn = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules))
        fn(h, w).block_until_ready()          # compile
        outs[name] = np.asarray(fn(h, w))
        walls[name] = time_best(lambda: fn(h, w).block_until_ready(), reps)
    set_tuning(overlap_streaming=False)
    err = float(np.abs(outs["streamed"] - outs["blocking"]).max())
    assert err < 1e-4, err
    return {"max_abs_err": err,
            "wall_blocking_s": walls["blocking"],
            "wall_streamed_s": walls["streamed"],
            "note": "CPU wall time measures dispatch cost only; the "
                    "overlap win needs async collectives (TPU)"}


def _prediction_section(load: int) -> Dict:
    """Serial vs max(comm, compute) finish on the production 2x16x16
    shape, and the ICI-vs-DCN roofline split of the aggregation bytes."""
    import numpy as np
    from repro.analysis.roofline import (PEAK_FLOPS, collective_split_seconds,
                                         serial_vs_overlap)
    from repro.core.collectives import hierarchical_byte_breakdown
    from repro.plan import (evaluate_split, plan, production_shape,
                            production_topology)

    topo = production_topology(multi_pod=True)
    shape = production_shape(True)
    serial = plan(topo, load, objective="PCCS")
    ov = plan(topo, load, objective="overlap")
    # cross pricing: each split under the other plane's cost model
    serial_k_overlapped = float(np.max(
        evaluate_split(topo, serial.k, load, objective="overlap")))
    ov_k_serial = float(np.max(
        evaluate_split(topo, ov.k, load, objective="PCCS")))

    # execution-plane aggregation of one bf16 load x load output layer:
    # ICI hops within the pod vs the shared DCN trunk, priced in seconds
    pod_size = int(np.prod(shape[1:]))
    bd = hierarchical_byte_breakdown(load * load, n_pods=shape[0],
                                     pod_size=pod_size)
    link = collective_split_seconds(bd["ici_per_device"], bd["dcn_per_pod"])
    comp_s = 2.0 * load ** 3 / (shape[0] * pod_size) / PEAK_FLOPS
    planes = serial_vs_overlap(comp_s, link["ici_s"], link["dcn_s"])
    return {
        "shape": list(shape), "load": load,
        "serial_plan": {"solver": serial.solver,
                        "finish": serial.finish_time,
                        "finish_overlapped": serial.finish_time_overlap,
                        "finish_of_split_on_overlap_plane":
                            serial_k_overlapped},
        "overlap_plan": {"solver": ov.solver, "finish": ov.finish_time,
                         "finish_of_split_on_serial_plane": ov_k_serial},
        "predicted_overlap_speedup":
            serial.finish_time / max(ov.finish_time, 1e-12),
        "roofline_split": {
            "ici_s": link["ici_s"], "dcn_s": link["dcn_s"],
            "compute_s": comp_s,
            "serial_bound_s": planes["serial_s"],
            "overlap_bound_s": planes["overlap_s"],
            "overlap_speedup": planes["overlap_speedup"],
            "bound": planes["overlap_bound"],
        },
    }


def _contention_section() -> Dict:
    """Drift-triggered work stealing over the static plan: the
    deterministic mid-run 2x slowdown scenario (``runtime.correct.
    simulate_correction``) on the canonical 8-node star.

    Emits the booleans ``check_regression.py`` gates on:

      steals_undisturbed_zero  hysteresis: unperturbed run never steals
      plan_identical_undisturbed  and its shares stay bit-identical
      steals_bounded           event count <= the policy budget
      spread_converged         final per-step finish spread back inside
                               the plan's own quantization tolerance
                               (computed HERE, same process as the sim)

    ``makespan_static`` is the static plan riding out the slowdown;
    ``makespan`` is the corrected run — serial vs overlap planes both
    reported, with the bidir hop depth for the streamed ring.
    """
    import numpy as np
    from repro.core.overlap import bidir_hops, sequential_hop_depth
    from repro.plan import StarTopology, plan
    from repro.runtime.correct import CorrectionPolicy, simulate_correction

    speeds = [1.0, 2.0, 4.0, 1.0, 1.0, 1.0, 2.0, 1.0]
    load, quantum = 8192, 128
    topo = StarTopology(w=1.0 / np.asarray(speeds),
                        z=np.full(len(speeds), 1e-9))
    pol = CorrectionPolicy(hysteresis=1.25, cooldown=1, max_corrections=12)
    out: Dict = {"speeds": speeds, "load": load, "quantum": quantum,
                 "slow_node": 2, "slow_factor": 2.0}
    for plane, objective, ring in (("train", "PCSS", 1),
                                   ("overlap", "overlap", 4)):
        pp = plan(topo, load, quantum=quantum, objective=objective)
        quiet = simulate_correction(pp, slow_node=None, n_steps=32,
                                    plane=plane, ring=ring, policy=pol)
        hot = simulate_correction(pp, slow_node=2, slow_at_frac=0.3,
                                  slow_factor=2.0, n_steps=32,
                                  plane=plane, ring=ring, policy=pol)
        out[plane] = {
            "undisturbed": quiet,
            "contended": hot,
            "serial_vs_corrected": {
                "makespan_static": hot["makespan_static"],
                "makespan_corrected": hot["makespan"],
                "speedup": hot["makespan_static"] / max(hot["makespan"],
                                                        1e-12),
            },
            "gates": {
                "steals_undisturbed_zero": quiet["steals"] == 0,
                "plan_identical_undisturbed":
                    quiet["final_k"] == quiet["seed_k"],
                "steals_bounded": hot["steals"] <= hot["steal_bound"],
                "spread_converged":
                    hot["spread_final"] <= hot["unit_tolerance"] + 1e-9,
                "makespan_improved":
                    hot["makespan"] < hot["makespan_static"],
            },
        }
    p = len(speeds)
    hf, hb = bidir_hops(p)
    out["bidir_hops"] = {
        "p": p, "forward": hf, "backward": hb,
        "depth_unidir": sequential_hop_depth("stream_scatter", p),
        "depth_bidir": sequential_hop_depth("stream_scatter_bidir", p),
    }
    return out


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small load + few reps for CI")
    ap.add_argument("--contention", action="store_true",
                    help="run only the work-stealing contention scenario")
    ap.add_argument("--load", type=int, default=8192)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    import jax
    n_dev = len(jax.devices())
    assert n_dev >= 8, (
        "benchmarks.overlap needs 8 host devices; run via `python -m "
        "benchmarks.overlap` (it re-execs itself with XLA_FLAGS set)")

    load, reps = (2048, 2) if args.smoke else (args.load, args.reps)

    contention = _contention_section()
    if args.contention:
        tr = contention["train"]
        print(f"contention: steals {tr['contended']['steals']} <= "
              f"{tr['contended']['steal_bound']}  spread "
              f"{tr['contended']['spread_final']:.4f} (tol "
              f"{tr['contended']['tolerance']:.4f})  makespan "
              f"{tr['serial_vs_corrected']['makespan_corrected']:.1f} vs "
              f"static {tr['serial_vs_corrected']['makespan_static']:.1f}")
        # a contention-only run is a PARTIAL artifact: never clobber the
        # committed full baseline at the default path (the regression
        # gate would fail on the missing sections)
        if args.out != DEFAULT_OUT:
            with open(args.out, "w") as f:
                json.dump({"contention": contention}, f, indent=2)
            print(f"wrote {args.out}")
        return {"contention": contention}

    structure = _structure_section(8)
    identity = _identity_section(reps)
    prediction = _prediction_section(load)

    result = {
        "workload": {"load": load, "reps": reps, "smoke": bool(args.smoke)},
        "structure": structure,
        "identity": identity,
        "prediction": prediction,
        "contention": contention,
    }

    mr = structure["model_ring"]
    br = structure["bidir_ring"]
    print(f"\nstructure : {mr['ppermutes']:.0f} ppermutes, "
          f"{mr['link_bytes_hlo']:.0f} B/device "
          f"(analytic {mr['link_bytes_analytic']:.0f} B), 0 all-gathers")
    print(f"bidir     : {br['forward']}+{br['backward']} fwd/bwd permutes, "
          f"hop depth {br['hop_depth']} vs {br['hop_depth_unidir']} unidir, "
          f"bytes identical")
    tr = contention["train"]
    print(f"contention: undisturbed {tr['undisturbed']['steals']} steals; "
          f"2x slowdown -> {tr['contended']['steals']} steals, spread "
          f"{tr['contended']['spread_final']:.4f} <= tol "
          f"{tr['contended']['tolerance']:.4f}, makespan x"
          f"{tr['serial_vs_corrected']['speedup']:.2f}")
    print(f"identity  : max |streamed - blocking| = "
          f"{identity['max_abs_err']:.2e}  "
          f"wall {identity['wall_streamed_s']*1e3:.1f}ms vs "
          f"{identity['wall_blocking_s']*1e3:.1f}ms (CPU dispatch)")
    rs = prediction["roofline_split"]
    print(f"prediction: {prediction['shape']} load {load}  "
          f"serial {prediction['serial_plan']['finish']:.1f} vs overlap "
          f"{prediction['overlap_plan']['finish']:.1f} "
          f"({prediction['predicted_overlap_speedup']:.2f}x)")
    print(f"roofline  : compute {rs['compute_s']*1e3:.2f}ms  "
          f"ici {rs['ici_s']*1e3:.2f}ms  dcn {rs['dcn_s']*1e3:.2f}ms  "
          f"-> serial {rs['serial_bound_s']*1e3:.2f}ms, overlapped "
          f"{rs['overlap_bound_s']*1e3:.2f}ms "
          f"({rs['overlap_speedup']:.2f}x, {rs['bound']}-bound)")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
