"""§Roofline report: three-term table for every (arch x shape) cell from
the dry-run artifacts (both meshes)."""

from __future__ import annotations

from typing import List

from repro.analysis.roofline import fmt_table, load_rows


def report(out) -> List[tuple]:
    rows_csv = []
    for mesh in ("16x16", "2x16x16"):
        rows = load_rows(mesh)
        if not rows:
            out(f"\n§Roofline [{mesh}]: no artifacts — run "
                f"`python -m repro.launch.dryrun --all"
                f"{' --multi-pod' if mesh != '16x16' else ''}` first")
            continue
        out(f"\n§Roofline — {mesh} mesh ({len(rows)} cells)")
        out(fmt_table(rows))
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        coll = max(rows, key=lambda r: r["collective_s"] /
                   max(r["compute_s"], 1e-12))
        rows_csv.append((f"roofline.{mesh}.cells", len(rows), "dry-run cells"))
        rows_csv.append((f"roofline.{mesh}.worst_fraction",
                         worst["roofline_fraction"],
                         f"{worst['arch']}/{worst['shape']}"))
        rows_csv.append((f"roofline.{mesh}.best_fraction",
                         best["roofline_fraction"],
                         f"{best['arch']}/{best['shape']}"))
        rows_csv.append((f"roofline.{mesh}.most_collective_bound",
                         coll["collective_s"] / max(coll["compute_s"], 1e-12),
                         f"{coll['arch']}/{coll['shape']}"))
    return rows_csv
