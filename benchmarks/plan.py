"""Planning quality + latency: flat star vs two-level hierarchical on the
production multi-pod shape (pod=2, data=16, model=16 — 512 devices).

  PYTHONPATH=src python -m benchmarks.plan [--smoke] [--out BENCH_plan.json]

The flat single-level star is the model every consumer hand-built before
``repro.plan``: it gives each remote device a *private* DCN channel, when
physically the pod shares one trunk.  Both plans are priced on the true
shared-trunk topology (``repro.plan.evaluate_split``), so the numbers are
the cost of the modeling error, not of the solver: predicted finish time,
DCN-crossing distribution volume, and the execution-plane aggregation
bytes per trunk (``core.collectives.hierarchical_byte_breakdown``).

Emits ``BENCH_plan.json`` for the perf trajectory (CI runs ``--smoke``).
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict

import numpy as np

from ._util import time_best

# default artifact location: the repository root, so the perf trajectory
# is tracked across PRs instead of vanishing into /tmp or CI workspaces
DEFAULT_OUT = str(pathlib.Path(__file__).resolve().parents[1]
                  / "BENCH_plan.json")


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small load + few reps for CI")
    ap.add_argument("--load", type=int, default=8192,
                    help="divisible units to split (layers / requests)")
    ap.add_argument("--quantum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=5,
                    help="latency reps; best per side is kept")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    from repro.core.collectives import hierarchical_byte_breakdown
    from repro.plan import (compare_flat_hierarchical, plan,
                            production_shape, production_topology)

    load, reps = (2048, 3) if args.smoke else (args.load, args.reps)
    topo = production_topology(multi_pod=True, seed=args.seed)
    shape = production_shape(True)

    lat_hier = time_best(
        lambda: plan(topo, load, quantum=args.quantum, objective="PCCS"),
        reps)
    flat_topo = topo.flatten()
    lat_flat = time_best(
        lambda: plan(flat_topo, load, quantum=args.quantum,
                     objective="PCCS"), reps)

    cmp = compare_flat_hierarchical(topo, load, quantum=args.quantum,
                                    objective="PCCS")
    hier, flat = cmp["hierarchical"], cmp["flat"]
    flat_comm = cmp["flat_comm_on_topology"]

    # execution-plane aggregation: bytes through each pod's DCN trunk for
    # one aggregated bf16 output layer of load x load
    agg = hierarchical_byte_breakdown(load * load, n_pods=shape[0],
                                      pod_size=int(np.prod(shape[1:])))

    result = {
        "workload": {"shape": list(shape), "p": topo.p, "load": load,
                     "quantum": args.quantum, "seed": args.seed,
                     "smoke": bool(args.smoke)},
        "flat": {
            "plan_latency_s": lat_flat,
            "finish_naive_model": flat.finish_time,
            "finish_on_topology": cmp["flat_finish_on_topology"],
            "comm_total": flat_comm.total,
            "comm_dcn": flat_comm.dcn,
        },
        "hierarchical": {
            "plan_latency_s": lat_hier,
            "finish": hier.finish_time,
            "comm_total": hier.comm.total,
            "comm_dcn": hier.comm.dcn,
            "pod_shares": hier.meta["pod_shares"],
            "solver": hier.solver,
        },
        "finish_speedup": cmp["finish_speedup"],
        "dcn_reduction": cmp["dcn_reduction"],
        "aggregation_dcn_per_pod": {
            "hierarchical_bytes": agg["dcn_per_pod"],
            "flat_allreduce_bytes": agg["flat_allreduce_dcn_per_pod"],
        },
    }

    print(f"\nplatform: {shape} = {topo.p} devices, load {load}, "
          f"quantum {args.quantum}")
    print(f"flat star:     finish(true) {cmp['flat_finish_on_topology']:11.1f}  "
          f"dcn {flat_comm.dcn/1e6:8.3f}M entries  "
          f"plan {lat_flat*1e3:6.1f}ms")
    print(f"hierarchical:  finish       {hier.finish_time:11.1f}  "
          f"dcn {hier.comm.dcn/1e6:8.3f}M entries  "
          f"plan {lat_hier*1e3:6.1f}ms  shares {hier.meta['pod_shares']}")
    print(f"finish speedup {cmp['finish_speedup']:.2f}x   "
          f"dcn reduction {cmp['dcn_reduction']*100:.1f}%   "
          f"agg trunk bytes {agg['dcn_per_pod']/1e6:.1f}MB vs "
          f"{agg['flat_allreduce_dcn_per_pod']/1e6:.1f}MB flat")
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
