"""Fig 6(a)/(b): 16-child star network — communication volume + finish time.

Paper setup (§6.1): 16 children, w*Tcp ~ U(0.0005, 0.0008),
z*Tcm ~ U(0.0002, 0.0005), PCCS mode, N = 100..1000, averages over
independent networks.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.integer_adjust import solve_integer
from repro.core.network import random_star
from repro.core.rect_partition import (even_col, lbp_volume, nrrp, peri_sum,
                                       rect_lower_bound_volume, recursive,
                                       speed_proportional_areas,
                                       star_finish_time)

NS = [100, 250, 500, 750, 1000]
TRIALS = 10
P = 16


def run() -> Dict[str, List[float]]:
    vol: Dict[str, List[float]] = {k: [] for k in
                                   ["LBP", "rect-LB", "NRRP", "Recursive",
                                    "PERI-SUM", "Even-Col"]}
    tf: Dict[str, List[float]] = {k: [] for k in
                                  ["LBP", "NRRP", "Recursive", "PERI-SUM",
                                   "Even-Col"]}
    for N in NS:
        acc_v = {k: 0.0 for k in vol}
        acc_t = {k: 0.0 for k in tf}
        for trial in range(TRIALS):
            net = random_star(P, seed=1000 * trial + N)
            f = speed_proportional_areas(net)
            parts = {"NRRP": nrrp(f), "Recursive": recursive(f),
                     "PERI-SUM": peri_sum(f), "Even-Col": even_col(P)}
            acc_v["LBP"] += lbp_volume(N)
            acc_v["rect-LB"] += rect_lower_bound_volume(f, N)
            for k, part in parts.items():
                acc_v[k] += part.comm_volume(N)
                acc_t[k] += star_finish_time(part, net, N)
            _, t = solve_integer(net, N, "PCCS")
            acc_t["LBP"] += t
        for k in vol:
            vol[k].append(acc_v[k] / TRIALS)
        for k in tf:
            tf[k].append(acc_t[k] / TRIALS)
    return {"N": NS, "volume": vol, "time": tf}


def report(out) -> List[str]:
    res = run()
    rows = []
    i_last = len(NS) - 1
    v = res["volume"]
    t = res["time"]
    out(f"\nFig 6(a) — star comm volume (entries, avg of {TRIALS} nets), N={NS}")
    for k in v:
        out(f"  {k:10s} " + " ".join(f"{x/1e6:9.3f}M" for x in v[k]))
    red_lb = 1 - v["LBP"][i_last] / v["rect-LB"][i_last]
    rows.append(("fig6a.lbp_reduction_vs_rect_lb_pct", red_lb * 100,
                 "paper claims 75%"))
    for name in ("NRRP", "Recursive", "PERI-SUM", "Even-Col"):
        red = 1 - v["LBP"][i_last] / v[name][i_last]
        rows.append((f"fig6a.lbp_reduction_vs_{name.lower()}_pct", red * 100,
                     "paper: 78/79.7/85.1/- %"))
    out(f"\nFig 6(b) — star finish time (s), PCCS, N={NS}")
    for k in t:
        out(f"  {k:10s} " + " ".join(f"{x:9.2f}" for x in t[k]))
    balanced = np.mean([t[k][i_last] for k in
                        ("LBP", "NRRP", "Recursive", "PERI-SUM")])
    rows.append(("fig6b.balanced_vs_evencol_pct",
                 (1 - balanced / t["Even-Col"][i_last]) * 100,
                 "paper claims ~40% smaller"))
    rows.append(("fig6b.lbp_vs_best_rect_pct",
                 (t["LBP"][i_last] / min(t[k][i_last] for k in
                  ("NRRP", "Recursive", "PERI-SUM")) - 1) * 100,
                 "paper: similar curves"))
    return rows
