"""The paper's technique end-to-end on (simulated) heterogeneous devices.

Eight CPU "devices" with different speeds; the star solver computes the
{k_i} split, the ragged LBP matmul executes it, and the three aggregation
modes (layers / allreduce / scatter) are compared for collective bytes on
the compiled HLO.

    PYTHONPATH=src python examples/heterogeneous_matmul.py
(re-executes itself with 8 host devices)
"""

import os
import subprocess
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    raise SystemExit(subprocess.run([sys.executable] + sys.argv, env=env).returncode)

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze_hlo
from repro.compat import make_mesh
from repro.core.lbp_matmul import lbp_matmul, lbp_matmul_heterogeneous, lbp_matmul_reference
from repro.core.partition import LayerAssignment
from repro.runtime.rebalance import plan_rebalance

mesh = make_mesh((8,), ("model",))

# --- straggler-aware split from measured speeds ---------------------------
speeds = [1.0, 1.0, 1.0, 0.5, 1.0, 2.0, 1.0, 1.0]   # device 3 slow, 5 fast
plan = plan_rebalance(K=1024, speeds=speeds, quantum=128)
print("measured speeds :", speeds)
print("k_i split       :", plan.assignment.k, f"(sum={plan.assignment.K})")
print(f"predicted speedup vs even split: {plan.predicted_speedup:.2f}x")

x = jax.random.normal(jax.random.PRNGKey(0), (64, 1024))
w = jax.random.normal(jax.random.PRNGKey(1), (1024, 256))
ref = lbp_matmul_reference(x, w)
out = jax.jit(lambda x, w: lbp_matmul_heterogeneous(
    x, w, plan.assignment, mesh, axis="model"))(x, w)
print("ragged matmul max err:", float(jnp.abs(out - ref).max()))

# --- aggregation modes: paper-faithful vs deferred ------------------------
print("\ncollective link bytes per step (compiled HLO, ring model):")
for mode in ("layers", "allreduce", "scatter"):
    c = jax.jit(lambda x, w: lbp_matmul(
        x, w, mesh, axis="model", mode=mode)).lower(x, w).compile()
    coll = analyze_hlo(c.as_text())["collectives"]
    print(f"  {mode:9s}: {coll['total_link_bytes']/1e3:8.1f} KB  {dict((k, int(v['count'])) for k, v in coll['per_op'].items())}")
print("\nlayers = the paper's distributed storage (no aggregation);")
print("scatter = deferred aggregation (reduce-scatter, half of allreduce).")
