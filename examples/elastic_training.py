"""Fault tolerance + elasticity demo (deliverable: FT story end-to-end).

1. trains with checkpoints;
2. a simulated device fault kills step 12; the trainer recovers from the
   last checkpoint and reproduces the uninterrupted trajectory exactly;
3. the LBP scheduler re-solves the layer split when the fleet shrinks
   (straggler appears / node dies) — the paper's §4 solver as the
   rebalancing brain.

    PYTHONPATH=src python examples/elastic_training.py
"""

import shutil

import numpy as np

from repro.configs import get_reduced
from repro.core.partition import LayerAssignment
from repro.runtime.rebalance import drop_devices, measure_speeds, plan_rebalance
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.sharding.rules import Rules

CKPT_A, CKPT_B = "/tmp/repro_elastic_a", "/tmp/repro_elastic_b"

cfg = get_reduced("llama3_2_3b")

# --- clean run ------------------------------------------------------------
shutil.rmtree(CKPT_A, ignore_errors=True)
clean = Trainer(cfg, Rules.null(),
                TrainerConfig(total_steps=20, checkpoint_every=5,
                              checkpoint_dir=CKPT_A),
                batch_size=4, seq_len=32).run()

# --- faulty run: device dies at step 12, recovery from checkpoint ---------
shutil.rmtree(CKPT_B, ignore_errors=True)
tr = Trainer(cfg, Rules.null(),
             TrainerConfig(total_steps=20, checkpoint_every=5,
                           checkpoint_dir=CKPT_B, inject_failure_at=12),
             batch_size=4, seq_len=32)
faulty = tr.run()
print(f"recoveries: {tr.recoveries}")

clean_by_step = {h["step"]: h["loss"] for h in clean}
drift = max(abs(h["loss"] - clean_by_step[h["step"]]) for h in faulty)
print(f"max post-recovery loss drift vs uninterrupted run: {drift:.2e}")
assert drift == 0.0, "recovery must be bit-identical"

# --- elastic rescale: the paper's solver re-splits the load ----------------
print("\nfleet of 8, device 5 starts straggling (2x slow):")
speeds = measure_speeds([1, 1, 1, 1, 1, 2.0, 1, 1])   # step times
plan = plan_rebalance(K=4096, speeds=speeds, quantum=128)
print("  new k_i:", plan.assignment.k, f" speedup {plan.predicted_speedup:.2f}x")

print("device 5 dies; re-solving over 7 survivors:")
plan2 = drop_devices(LayerAssignment.even(4096, 8, quantum=128), dead=[5],
                     speeds=[1] * 8, quantum=128)
print("  new k_i:", plan2.assignment.k, f"(p={plan2.assignment.p})")
print("\nrestore onto the new fleet = checkpoint.load_checkpoint with the "
      "new mesh's shardings (reshard-on-restore, tested in tests/).")
