"""Fleet runtime demo: 3 heterogeneous replicas, mid-run kill + join.

    PYTHONPATH=src python examples/fleet_serve.py [--arch llama3_2_3b]

Serves a staggered workload through ``repro.fleet`` — async front-end
with backpressure, capacity-planned routing, one replica killed while
its requests are mid-decode and a fresh one joining later — and shows
the fleet oracle invariant: every token stream is byte-identical to
per-request ``greedy_generate`` despite the rescale (the controller
requeues the dead replica's outstanding work exactly once).  Ends with
the resharding checkpoint: params saved under the fleet's plan restore
bit-identical re-sliced for a different topology.
"""

import argparse
import asyncio

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.fleet import FaultPlan, FleetController, FleetFrontend, Replica
from repro.models import transformer as T
from repro.serve import EngineConfig, TransformerModel, greedy_generate
from repro.serve.engine import synthetic_workload
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    workload = synthetic_workload(args.requests, cfg.vocab_size,
                                  lens=(6, 10, 16), news=(3, 6, 9),
                                  stagger=0.5)

    # ONE slot-plane adapter serves every replica (the cache is an
    # argument, so the compiled steps are shared fleet-wide)
    model = TransformerModel(params, cfg, rules)
    ec = EngineConfig(n_slots=2, max_prompt_len=16, max_new_cap=9,
                      cache_len=25)
    replicas = [
        Replica("r0", model, ec, rate=1.0,
                fault=FaultPlan(kill_at=5)),        # crashes mid-decode
        Replica("r1", model, ec, rate=2.0),
        Replica("r2", model, ec, rate=0.5),
    ]
    controller = FleetController(replicas, miss_threshold=3)
    controller.schedule_join(Replica("r3", model, ec, rate=1.5),
                             at_tick=8)
    frontend = FleetFrontend(controller, max_pending=6)

    async def serve():
        streamed = []

        async def stream_first():
            async for tok in frontend.stream(0):
                streamed.append(tok)

        consumer = asyncio.ensure_future(stream_first())
        for prompt, max_new, arrival in workload:
            await frontend.submit(prompt, max_new, arrival=arrival)
        report = await frontend.drain()
        await consumer
        return report, streamed

    report, streamed = asyncio.run(serve())

    print(f"{cfg.name}: {args.requests} requests on a 3-replica fleet "
          f"(rates 1.0/2.0/0.5), kill r0 @ step 5, join r3 @ tick 8")
    print(f"  ticks={report.ticks} completed={report.n_completed} "
          f"requeues={report.requeues}")
    for ev in report.events:
        print(f"  event: {ev}")
    for name in sorted(report.occupancy):
        print(f"  {name}: occupancy {report.occupancy[name]:.2f}, "
              f"decode tokens {report.decode_tokens[name]}")
    print(f"  streamed rid 0 live: {streamed}")

    # fleet oracle: byte-identical to per-request greedy_generate
    for rid, (prompt, max_new, _) in enumerate(workload):
        ref = np.asarray(greedy_generate(params, cfg, rules,
                                         np.asarray(prompt)[None],
                                         max_new=max_new))[0]
        assert np.array_equal(ref, report.completed[rid]), rid
    assert streamed == list(map(int, report.completed[0]))
    print("  oracle: every stream token-identical under the kill/join "
          "schedule")

    # --- resharding checkpoint: same weights, different topology ---------
    import tempfile
    from repro.checkpoint import restore_resharded, save_sharded
    from repro.plan import StarTopology, plan

    K = cfg.d_model if cfg.d_model % 4 == 0 else 64
    demo_state = {"w": np.arange(K * 4, dtype=np.float32).reshape(K, 4)}
    plan_a = plan(StarTopology.from_speeds(np.array([1.0, 2.0, 0.5])), K,
                  quantum=1)
    plan_b = plan(StarTopology.from_speeds(np.array([1.0, 1.0, 1.0, 1.0])),
                  K, quantum=1)
    with tempfile.TemporaryDirectory() as d:
        save_sharded(d, 1, demo_state, plan_a)
        _, full, shards = restore_resharded(d, 1, demo_state, plan_b)
    assert np.array_equal(full["w"], demo_state["w"])
    print(f"\nreshard checkpoint: saved under shares "
          f"{plan_a.k.tolist()}, restored bit-identical re-sliced to "
          f"{[s['w'].shape[0] for s in shards]} (plan "
          f"{plan_b.k.tolist()})")


if __name__ == "__main__":
    main()
