"""Quickstart: the paper's LBP scheduling + the distributed LBP matmul.

Runs on this CPU container:
    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.network import random_star, random_mesh
from repro.core.star import solve, per_processor_finish
from repro.core.integer_adjust import solve_integer
from repro.core.pmft import pmft_lbp
from repro.core.heuristic import mft_lbp_heuristic
from repro.core.rect_partition import (lbp_volume, peri_sum,
                                       rect_lower_bound_volume,
                                       speed_proportional_areas)

# --- 1. LBP on a heterogeneous star network (paper §4) -------------------
N = 600
net = random_star(16, seed=0)
for mode in ("SCSS", "SCCS", "PCCS", "PCSS"):
    s = solve(net, N, mode)
    spread = per_processor_finish(net, N, s.k, mode)
    print(f"{mode}: T_f={s.finish_time:9.2f}s  comm={s.comm_volume/1e6:.2f}M "
          f"(=2N^2)  equal-finish spread={spread.max()-spread.min():.2e}")

k_int, tf = solve_integer(net, N, "PCCS")
print(f"integer split (§4.5): sum={k_int.sum()}  T_f={tf:.2f}s")

# --- 2. Communication optimality (Theorem 1 vs rectangular) --------------
f = speed_proportional_areas(net)
print(f"\nLBP volume      : {lbp_volume(N)/1e6:.2f}M entries (2N^2, optimal)")
print(f"rect lower bound: {rect_lower_bound_volume(f, N)/1e6:.2f}M entries")
print(f"PERI-SUM        : {peri_sum(f).comm_volume(N)/1e6:.2f}M entries")

# --- 3. Mesh scheduling via the MFT-LBP linear program (paper §5) --------
mesh_net = random_mesh(5, 5, seed=1)
sched = pmft_lbp(mesh_net, 400)
heur = mft_lbp_heuristic(mesh_net, 400)
print(f"\n5x5 mesh: PMFT-LBP T_f={sched.t_finish:.1f}s "
      f"({sched.simplex_iters} simplex iters); "
      f"heuristic T_f={heur.t_finish:.1f}s ({heur.simplex_iters} iters)")
print(f"k per node:\n{sched.k.reshape(5, 5)}")
