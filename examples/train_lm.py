"""End-to-end training driver (deliverable b): train a reduced LM for a few
hundred steps with the full substrate — synthetic pipeline, AdamW + cosine,
remat + grad accumulation, async checkpoints, resume, loss descending.

    PYTHONPATH=src python examples/train_lm.py [--arch llama3_2_3b] [--steps 300]

Any of the 10 assigned archs works (--arch olmoe_1b_7b exercises MoE,
--arch recurrentgemma_9b the RG-LRU hybrid, --arch xlstm_1_3b the sLSTM/mLSTM
stack).  ~100M-param variants: --width 512 --layers 8 (slower).
"""

import argparse
import shutil

from repro.configs import ARCH_IDS, get_reduced
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--width", type=int, default=0, help="override d_model")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_example_train")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    over = {}
    if args.width:
        over["d_model"] = args.width
        over["head_dim"] = args.width // cfg.n_heads
    if args.layers:
        over["n_layers"] = args.layers
    if over:
        import dataclasses
        cfg = dataclasses.replace(cfg, **over)

    shutil.rmtree(args.ckpt, ignore_errors=True)
    tr = Trainer(cfg, Rules.null(),
                 TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                               checkpoint_dir=args.ckpt,
                               grad_accum=args.grad_accum),
                 batch_size=args.batch, seq_len=args.seq)
    print(f"training {cfg.name}: {sum(1 for _ in [0])} ...")
    hist = tr.run()
    for m in hist:
        if m["step"] % 25 == 0 or m["step"] == args.steps - 1:
            print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  {m['dt']*1e3:.0f} ms")
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first - 0.05 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
