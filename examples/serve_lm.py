"""Batched serving example: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python examples/serve_lm.py [--arch recurrentgemma_9b]

Exercises the KV-cache / RG-LRU-state / mLSTM-state serving paths and
verifies the decoded continuation against the full-forward logits.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.models import transformer as T
from repro.serve.step import greedy_generate, make_decode_step
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)

    t0 = time.time()
    out = greedy_generate(params, cfg, rules, prompt, max_new=args.max_new)
    dt = time.time() - t0
    print(f"{cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new} -> {args.batch*args.max_new/dt:.1f} tok/s")
    for b in range(min(2, args.batch)):
        print(f"  row {b}: {list(map(int, out[b]))}")

    # consistency: greedy first token == argmax of full-forward logits
    full = jnp.concatenate([prompt, out[:, :0]], axis=1)
    hid, _ = T.forward_hidden(params, cfg, rules, full, remat=False)
    from repro.models.layers import rms_norm
    hN = rms_norm(hid, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", hN[:, -1].astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    ok = bool(jnp.all(jnp.argmax(logits, -1) == out[:, 0]))
    print(f"decode == full-forward argmax: {ok}")
    assert ok


if __name__ == "__main__":
    main()
