"""Prefix sharing + copy-on-write on the paged KV plane.

    PYTHONPATH=src python examples/prefix_sharing.py [--arch llama3_2_3b]

Serves 32 requests drawn from 4 shared system-prompt templates through
the paged serving engine twice — once with worst-case private page
reservation, once with ``prefix_sharing`` — and shows the capacity win:
matching prompts attach to the SAME physical prompt pages (refcounted),
each request privately claims only its divergent suffix + decode pages
(the copy-on-write), and peak pages-in-use drops while every token stays
identical to the non-sharing plane AND to the ``greedy_generate``
oracle.  The contract behind this demo is documented in
``docs/serving.md``.
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.models import transformer as T
from repro.serve import (EngineConfig, PagedTransformerModel,
                         ServingEngine, greedy_generate)
from repro.serve.engine import shared_prefix_workload
from repro.sharding.rules import Rules

PAGE_SIZE = 4


def run(params, cfg, rules, workload, *, sharing):
    eng = ServingEngine(
        PagedTransformerModel(params, cfg, rules),
        EngineConfig(n_slots=8, max_prompt_len=28, max_new_cap=16,
                     cache_len=44, page_size=PAGE_SIZE,
                     prefix_sharing=sharing))
    for prompt, max_new, arrival in workload:
        eng.submit(prompt, max_new, arrival=arrival)
    return eng, eng.run()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    # 32 requests over 4 templates: each prompt = 16-token template
    # (4 full shareable pages) + a private 4..12-token suffix.
    workload = shared_prefix_workload(32, cfg.vocab_size, n_templates=4,
                                      template_len=16, suffix_lens=(4, 8, 12),
                                      news=(4, 8, 12, 16), stagger=0.5)
    print(f"{cfg.name}: 32 requests over 4 shared {16}-token templates "
          f"(page_size={PAGE_SIZE})")

    eng_off, rep_off = run(params, cfg, rules, workload, sharing=False)
    eng_on, rep_on = run(params, cfg, rules, workload, sharing=True)

    print(f"\n  {'':22s}{'sharing off':>12s}{'sharing on':>12s}")
    print(f"  {'peak pages in use':22s}{eng_off.pool.peak_used_pages:>12d}"
          f"{eng_on.pool.peak_used_pages:>12d}")
    print(f"  {'pages allocated':22s}{eng_off.pool.n_allocated:>12d}"
          f"{eng_on.pool.n_allocated:>12d}")
    print(f"  {'shared attaches':22s}{eng_off.pool.n_shared_attached:>12d}"
          f"{eng_on.pool.n_shared_attached:>12d}")
    print(f"  {'max refcount':22s}{eng_off.pool.max_refcount:>12d}"
          f"{eng_on.pool.max_refcount:>12d}")
    ratio = eng_off.pool.peak_used_pages / max(eng_on.pool.peak_used_pages, 1)
    print(f"  capacity ratio (peak off / peak on): {ratio:.2f}x")

    # token identity: sharing vs non-sharing, and both vs the oracle
    identical = all(np.array_equal(rep_off.completed[rid],
                                   rep_on.completed[rid])
                    for rid in rep_off.completed)
    print(f"\n  sharing token-identical to non-sharing plane: {identical}")
    assert identical
    for rid in (0, 15, 31):
        prompt, max_new, _ = workload[rid]
        ref = np.asarray(greedy_generate(params, cfg, rules,
                                         np.asarray(prompt)[None],
                                         max_new=max_new))[0]
        assert np.array_equal(ref, rep_on.completed[rid]), rid
    print("  oracle spot-check (rids 0/15/31): token-identical")

    assert eng_on.pool.n_shared_attached > 0
    assert eng_on.pool.peak_used_pages < eng_off.pool.peak_used_pages
    assert eng_on.pool.n_allocated == eng_on.pool.n_freed
    print("  drained clean: n_allocated == n_freed, prefix index empty "
          f"({len(eng_on.pool.prefix_index)} entries)")


if __name__ == "__main__":
    main()
