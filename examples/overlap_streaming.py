"""Stream vs blocking distributed matmul: "simultaneous start" on a mesh.

The paper's core scheduling claim is that layer decomposition lets the
distribution of layer j+1 overlap the multiplication of layer j, so the
finish time is max(comm, compute) instead of their sum.  This demo runs
both execution planes on an 8-device mesh:

  blocking   all-gather the FSDP weight -> one big einsum -> one
             psum_scatter of the partial layer;
  streamed   the weight shard rides a ppermute ring (one column block
             matmul'd per hop) and the aggregation is an
             accumulate-and-forward tile ring — zero monolithic
             collectives in the lowered HLO.

    PYTHONPATH=src python examples/overlap_streaming.py
(re-executes itself with 8 host devices)
"""

import os
import subprocess
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    raise SystemExit(subprocess.run([sys.executable] + sys.argv,
                                    env=env).returncode)

import jax
import numpy as np

from repro.analysis.hlo_collectives import collective_summary
from repro.compat import make_mesh
from repro.core import collectives
from repro.core.lbp_matmul import lbp_matmul, lbp_matmul_reference
from repro.models import lbp_linear
from repro.models.tuning import set_tuning
from repro.plan import plan, production_topology
from repro.sharding.rules import Rules

# --- 1. the same LBP matmul under blocking and streamed aggregation -------
mesh = make_mesh((8,), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 512))
w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
ref = np.asarray(lbp_matmul_reference(x, w))

print("mode              max|err|   ppermutes  AG/AR/RS   link B/device")
for mode in ("allreduce", "scatter", "stream_gather", "stream_scatter"):
    fn = jax.jit(lambda x, w, m=mode: lbp_matmul(x, w, mesh, axis="model",
                                                 mode=m))
    err = np.abs(np.asarray(fn(x, w)) - ref).max()
    summ = collective_summary(fn.lower(x, w).compile().as_text(), 8)
    per_op = summ["per_op"]
    n_pp = per_op.get("collective-permute", {}).get("count", 0)
    n_blk = sum(per_op.get(op, {}).get("count", 0)
                for op in ("all-gather", "all-reduce", "reduce-scatter"))
    analytic = collectives.collective_bytes_per_device(
        x.shape[0] * x.shape[1] * w.shape[1], 8, mode, itemsize=4)
    print(f"{mode:16s}  {err:8.1e}   {n_pp:9.0f}  {n_blk:8.0f}   "
          f"{analytic:12.0f}")

# --- 2. the full row-parallel layer with the FSDP weight ring -------------
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = Rules(batch=("pod", "data"), seq="model", embed="data", ff="model",
              mesh=mesh3)
h = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 64))
wf = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
outs = {}
for name, streaming in (("blocking", False), ("streamed", True)):
    set_tuning(explicit_lbp_scatter=True, overlap_streaming=streaming)
    fn = jax.jit(lambda h, w: lbp_linear.lbp_row_parallel(h, w, rules))
    outs[name] = np.asarray(fn(h, wf))
    summ = collective_summary(fn.lower(h, wf).compile().as_text(), 8)
    print(f"{name:9s} lbp_row_parallel collectives: "
          f"{ {k: v['count'] for k, v in summ['per_op'].items()} }")
set_tuning(explicit_lbp_scatter=False, overlap_streaming=False)
print("streamed == blocking:",
      np.abs(outs["streamed"] - outs["blocking"]).max() < 1e-4)

# --- 3. what the planner predicts the overlap is worth --------------------
topo = production_topology(multi_pod=True)
serial = plan(topo, 2048, objective="PCCS")
ov = plan(topo, 2048, objective="overlap")
print(f"\nproduction 2x16x16, load 2048:")
print(f"  serial  (PCCS)    finish {serial.finish_time:10.1f}  "
      f"(its overlapped price: {serial.finish_time_overlap:10.1f})")
print(f"  overlap objective finish {ov.finish_time:10.1f}  "
      f"-> {serial.finish_time / ov.finish_time:.2f}x predicted")
