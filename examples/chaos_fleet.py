"""Chaos demo: a composite fault schedule rendered on one Perfetto axis.

    PYTHONPATH=src python examples/chaos_fleet.py [--arch llama3_2_3b]

Drives the deterministic chaos harness (``repro.fleet.chaos``) through
every fault domain at once — and the whole recovery story lands on the
controller track as tick-addressed instants you can scrub through at
https://ui.perfetto.dev:

  * ``r_kill`` dies at tick 6: a ``kill`` instant, exactly-once
    ``requeue`` marks for its in-flight requests, then a ``restore``
    instant where the controller falls back to the newest intact
    snapshot and re-slices it onto the survivor plan (``replan`` marks
    the new shares);
  * ``r_flaky`` raises transient step errors at ticks 3-4: each failed
    attempt is a ``retry`` instant annotated with the attempt number
    and its capped-exponential backoff (1, 2, 4, ... ticks on the TICK
    clock — zero wall-clock is spent waiting), and the incident closes
    with a ``recover`` instant.  During backoff the replica's track
    simply goes quiet; the heartbeat plane never fires because a failed
    attempt proves liveness;
  * ``r_torn`` is slowed 2x AND tears its own checkpoint shards from
    tick 2 on (truncated ``.npy`` payloads): every later snapshot of
    its shard fails sha256 verification at restore time, so the
    ``restore`` instants show the scan SKIPPING corrupt epochs
    (``corrupt_shard`` instants) and landing on the older intact one;
  * ``joiner`` arrives at tick 10: a ``join`` instant followed by its
    own ``restore`` + ``replan`` — the checkpointed state re-sliced
    onto the grown fleet.

All timestamps come from the controller's tick counter, so this script
is a determinism witness too: re-running it writes a byte-identical
trace JSON (the property tier-1 pins for the benchmark twin of this
schedule).  The verdict line printed at the end is the same structural
reduction ``benchmarks/check_regression.py`` gates in CI.
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.fleet import (ChaosReplicaSpec, ChaosSchedule, FaultPlan,
                         Replica, RetryPolicy, chaos_verdicts, run_chaos)
from repro.models import transformer as T
from repro.obs import MetricsRegistry, Tracer, write_chrome_trace
from repro.serve import EngineConfig, TransformerModel, greedy_generate
from repro.serve.engine import synthetic_workload
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--trace-out", default="/tmp/chaos_trace.json")
    ap.add_argument("--metrics-out", default="/tmp/chaos_metrics.json")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    workload = synthetic_workload(args.requests, cfg.vocab_size,
                                  lens=(8,), news=(6,), stagger=0.5)

    tracer, metrics = Tracer(), MetricsRegistry()
    model = TransformerModel(params, cfg, rules)   # shared adapter
    ec = EngineConfig(n_slots=2, max_prompt_len=16, max_new_cap=9,
                      cache_len=25)

    def mk(name, rate, fault):
        return Replica(name, model, ec, rate=rate, fault=fault,
                       tracer=tracer, metrics=metrics)

    schedule = ChaosSchedule(
        replicas=(
            ChaosReplicaSpec("r_kill", rate=1.0,
                             fault=FaultPlan(kill_at=6)),
            ChaosReplicaSpec("r_flaky", rate=2.0,
                             fault=FaultPlan(transient_at=3,
                                             transient_for=2)),
            ChaosReplicaSpec("r_torn", rate=1.0,
                             fault=FaultPlan(slow_at=2, slow_factor=2,
                                             torn_shard_at=2)),
            ChaosReplicaSpec("r_anchor", rate=1.5),
        ),
        join_at=10, join_name="joiner", join_rate=1.0,
        checkpoint_every=4)
    state = {"w": np.arange(1024 * 4,
                            dtype=np.float32).reshape(1024, 4),
             "bias": np.arange(8, dtype=np.float32)}

    with tempfile.TemporaryDirectory(prefix="chaos_ckpt_") as ckpt:
        ctrl, report = run_chaos(
            schedule, mk, workload,
            retry=RetryPolicy(max_retries=3, backoff_base=1,
                              backoff_cap=8),
            checkpoint_dir=ckpt, checkpoint_state=state,
            tracer=tracer, metrics=metrics)

    reference = {
        rid: np.asarray(greedy_generate(
            params, cfg, rules, np.asarray(prompt)[None],
            max_new=max_new))[0]
        for rid, (prompt, max_new, _) in enumerate(workload)}
    v = chaos_verdicts(schedule, report, workload, reference)

    print(f"{cfg.name}: {args.requests} requests through "
          f"{len(schedule.replicas)} replicas under composite faults "
          f"(kill@6, transient@3x2, slow+torn@2, join@10, ckpt every "
          f"{schedule.checkpoint_every})")
    print(f"drained in {report.ticks} ticks: "
          f"{v['completed']}/{v['requests']} completed, "
          f"{v['retries']} retries -> {v['recoveries']} recovered, "
          f"{v['restores']} restores ({v['corrupt_shards']} torn "
          f"snapshots skipped), requeued {v['requeues']}")
    marks = {}
    for e in tracer.events:
        marks[e["name"]] = marks.get(e["name"], 0) + 1
    shown = ["retry", "recover", "checkpoint", "corrupt_shard",
             "restore", "kill", "join", "requeue", "replan"]
    print("controller-track instants: " +
          "  ".join(f"{n}={marks.get(n, 0)}" for n in shown))
    gates = "  ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                      for k, ok in v["gates"].items())
    print(f"verdicts: {gates}")
    print(f"trace: {len(tracer)} events on "
          f"{len({e['track'] for e in tracer.events})} tracks")
    print(f"wrote {write_chrome_trace(tracer, args.trace_out)} "
          f"— open at https://ui.perfetto.dev")
    print(f"wrote {metrics.write_json(args.metrics_out)}")


if __name__ == "__main__":
    main()
