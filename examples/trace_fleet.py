"""Observability demo: trace a kill+join fleet run, open it in Perfetto.

    PYTHONPATH=src python examples/trace_fleet.py [--arch llama3_2_3b]

Runs the elastic-rescale fleet scenario (3 heterogeneous replicas, one
killed mid-decode, one CONTENDED at 3x from step 2 — alive but slow —
and one joining later) with work stealing enabled and ONE shared
``obs.Tracer`` and ``obs.MetricsRegistry`` threaded through every layer:

  * each replica's engine records per-request lanes (queue-wait ->
    serve -> retire) and an ``engine`` lane (prefill / fused-decode
    spans) on its own track;
  * the controller records routing, kill/join/requeue and replan events
    on a ``controller`` track, and overrides the timeline with its tick
    counter so the whole fleet renders on one axis;
  * the drift corrector marks every work steal on the SAME controller
    track, lane ``correction``: a ``steal`` instant (src/dst/amount/
    drift, from ``runtime.correct``) when the ``fleet_drift`` gauge
    trips its hysteresis threshold, and one ``shed`` instant per
    requeued request — in Perfetto, look for the correction lane's
    instants lining up with the contended replica's stalled engine
    spans, followed by the replan that rebuilds the shares;
  * the registry counts requeues, steals, admission rejections by
    reason, heartbeat misses, and gauges queue depth / pool occupancy /
    the plan-vs-actual ``fleet_drift`` signal (reset to 0 at every
    replan instant, so the sawtooth in the gauge track IS the
    replan history).

Because every timestamp comes from the tick clock (never the wall
clock), re-running this script produces a byte-identical trace.json —
the property the tier-1 determinism tests pin.

Open the trace at https://ui.perfetto.dev (or chrome://tracing).
"""

import argparse

import jax

from repro.configs import ARCH_IDS, get_reduced
from repro.fleet import FaultPlan, FleetController, FleetFrontend, Replica
from repro.runtime.correct import CorrectionPolicy
from repro.models import transformer as T
from repro.obs import MetricsRegistry, Tracer, write_chrome_trace
from repro.serve import EngineConfig, TransformerModel
from repro.serve.engine import synthetic_workload
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--trace-out", default="/tmp/fleet_trace.json")
    ap.add_argument("--metrics-out", default="/tmp/fleet_metrics.json")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    # saturated uniform shapes keep the contended replica's queue backed
    # up long enough for the drift window to fill — the regime where the
    # corrector is designed (and tier-1-tested) to fire
    workload = synthetic_workload(args.requests, cfg.vocab_size,
                                  lens=(8,), news=(6,),
                                  stagger=0.25)

    tracer, metrics = Tracer(), MetricsRegistry()
    model = TransformerModel(params, cfg, rules)   # shared adapter
    ec = EngineConfig(n_slots=2, max_prompt_len=16, max_new_cap=9,
                      cache_len=25)
    replicas = [
        Replica("r0", model, ec, rate=1.0, fault=FaultPlan(kill_at=5),
                tracer=tracer, metrics=metrics),
        Replica("r1", model, ec, rate=2.0, tracer=tracer, metrics=metrics),
        # contended, not dead: cataloged healthy (rate 1.0) but from step
        # 2 on it beats its heartbeat while only working every 4th step —
        # the drift corrector's case, not the health plane's
        Replica("r2", model, ec, rate=1.0,
                fault=FaultPlan(slow_at=2, slow_factor=4),
                tracer=tracer, metrics=metrics),
    ]
    # an eager steal policy so the short demo trips visibly; production
    # default (steal_policy=None) waits for a larger drift window
    controller = FleetController(
        replicas, miss_threshold=8, steal=True,
        steal_policy=CorrectionPolicy(hysteresis=1.25, cooldown=2,
                                      max_corrections=8, persistence=2,
                                      min_window=24.0),
        tracer=tracer, metrics=metrics)
    controller.schedule_join(
        Replica("r3", model, ec, rate=1.5, tracer=tracer, metrics=metrics),
        at_tick=8)
    frontend = FleetFrontend(controller, max_pending=6)
    report = frontend.serve(workload)

    print(f"{cfg.name}: {args.requests} requests, kill r0 @ step 5, "
          f"slow r2 4x @ step 2, join r3 @ tick 8 -> "
          f"{report.n_completed} completed in "
          f"{report.ticks} ticks, {report.requeues} requeued, "
          f"{report.steals} stolen")
    requeues = [e for e in tracer.events if e["name"] == "requeue"]
    steal_marks = [e for e in tracer.events
                   if e.get("lane") == "correction"
                   and e["name"] == "steal"]
    shed_marks = [e for e in tracer.events
                  if e.get("lane") == "correction" and e["name"] == "shed"]
    print(f"trace: {len(tracer)} events on "
          f"{len({e['track'] for e in tracer.events})} tracks "
          f"({len(requeues)} requeue marks at the kill tick; correction "
          f"lane: {len(steal_marks)} steal + {len(shed_marks)} shed "
          f"instants)")
    snap = metrics.snapshot()
    # the counter counts corrector TRIPS; the report counts APPLIED
    # steals — a trip with no queued backlog to shed is suppressed
    print(f"metrics: requeues={snap['counters'].get('requeues', 0)} "
          f"steal_trips={snap['counters'].get('steals', 0)} "
          f"applied={report.steals} "
          f"fleet_drift={snap['gauges'].get('fleet_drift', 0.0):.4f}")
    print(f"wrote {write_chrome_trace(tracer, args.trace_out)} "
          f"— open at https://ui.perfetto.dev")
    print(f"wrote {metrics.write_json(args.metrics_out)}")


if __name__ == "__main__":
    main()
