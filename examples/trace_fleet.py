"""Observability demo: trace a kill+join fleet run, open it in Perfetto.

    PYTHONPATH=src python examples/trace_fleet.py [--arch llama3_2_3b]

Runs the elastic-rescale fleet scenario (3 heterogeneous replicas, one
killed mid-decode, one joining later) with ONE shared ``obs.Tracer`` and
``obs.MetricsRegistry`` threaded through every layer:

  * each replica's engine records per-request lanes (queue-wait ->
    serve -> retire) and an ``engine`` lane (prefill / fused-decode
    spans) on its own track;
  * the controller records routing, kill/join/requeue and replan events
    on a ``controller`` track, and overrides the timeline with its tick
    counter so the whole fleet renders on one axis;
  * the registry counts requeues, admission rejections by reason,
    heartbeat misses, and gauges queue depth / pool occupancy / the
    plan-vs-actual ``fleet_drift`` signal.

Because every timestamp comes from the tick clock (never the wall
clock), re-running this script produces a byte-identical trace.json —
the property the tier-1 determinism tests pin.

Open the trace at https://ui.perfetto.dev (or chrome://tracing).
"""

import argparse

import jax

from repro.configs import ARCH_IDS, get_reduced
from repro.fleet import FaultPlan, FleetController, FleetFrontend, Replica
from repro.models import transformer as T
from repro.obs import MetricsRegistry, Tracer, write_chrome_trace
from repro.serve import EngineConfig, TransformerModel
from repro.serve.engine import synthetic_workload
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--trace-out", default="/tmp/fleet_trace.json")
    ap.add_argument("--metrics-out", default="/tmp/fleet_metrics.json")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    workload = synthetic_workload(args.requests, cfg.vocab_size,
                                  lens=(6, 10, 16), news=(3, 6, 9),
                                  stagger=0.5)

    tracer, metrics = Tracer(), MetricsRegistry()
    model = TransformerModel(params, cfg, rules)   # shared adapter
    ec = EngineConfig(n_slots=2, max_prompt_len=16, max_new_cap=9,
                      cache_len=25)
    replicas = [
        Replica("r0", model, ec, rate=1.0, fault=FaultPlan(kill_at=5),
                tracer=tracer, metrics=metrics),
        Replica("r1", model, ec, rate=2.0, tracer=tracer, metrics=metrics),
        Replica("r2", model, ec, rate=0.5, tracer=tracer, metrics=metrics),
    ]
    controller = FleetController(replicas, miss_threshold=3,
                                 tracer=tracer, metrics=metrics)
    controller.schedule_join(
        Replica("r3", model, ec, rate=1.5, tracer=tracer, metrics=metrics),
        at_tick=8)
    frontend = FleetFrontend(controller, max_pending=6)
    report = frontend.serve(workload)

    print(f"{cfg.name}: {args.requests} requests, kill r0 @ step 5, "
          f"join r3 @ tick 8 -> {report.n_completed} completed in "
          f"{report.ticks} ticks, {report.requeues} requeued")
    requeues = [e for e in tracer.events if e["name"] == "requeue"]
    print(f"trace: {len(tracer)} events on "
          f"{len({e['track'] for e in tracer.events})} tracks "
          f"({len(requeues)} requeue marks at the kill tick)")
    snap = metrics.snapshot()
    print(f"metrics: requeues={snap['counters'].get('requeues', 0)} "
          f"fleet_drift={snap['gauges'].get('fleet_drift', 0.0):.4f}")
    print(f"wrote {write_chrome_trace(tracer, args.trace_out)} "
          f"— open at https://ui.perfetto.dev")
    print(f"wrote {metrics.write_json(args.metrics_out)}")


if __name__ == "__main__":
    main()
