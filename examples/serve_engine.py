"""Continuous-batching serving engine demo.

    PYTHONPATH=src python examples/serve_engine.py [--arch llama3_2_3b]

Serves a staggered-arrival workload of mixed-length requests through
``repro.serve.engine``, verifies a few outputs against the
``greedy_generate`` oracle, replays the SAME workload on the paged KV
plane (fixed-size token pages + per-request page tables — token-identical
by construction, with visible fragmentation), then shows the LBP capacity
planner splitting traffic across heterogeneous replicas with the §4 star
solvers (re-planning on drift, and memory-honest page-capacity splits).
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.models import transformer as T
from repro.serve import (CapacityPlanner, EngineConfig,
                         PagedTransformerModel, ServingEngine,
                         TransformerModel, greedy_generate)
from repro.sharding.rules import Rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    rules = Rules.null()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    from repro.serve.engine import synthetic_workload
    workload = synthetic_workload(args.requests, cfg.vocab_size,
                                  lens=(6, 10, 16, 24), news=(2, 4, 8, 12),
                                  stagger=0.5)

    engine = ServingEngine(TransformerModel(params, cfg, rules),
                           EngineConfig(n_slots=args.slots,
                                        max_prompt_len=24, max_new_cap=12,
                                        cache_len=36))
    for prompt, max_new, arrival in workload:
        engine.submit(prompt, max_new, arrival=arrival)
    rep = engine.run()

    print(f"{cfg.name}: {args.requests} staggered requests on "
          f"{args.slots} slots")
    print(f"  {rep.tokens_per_sec:.1f} tok/s aggregate, occupancy "
          f"{rep.occupancy:.2f}, TTFT mean {rep.ttft_mean*1e3:.0f}ms")
    print(f"  rid arrival S  max_new  first tokens")
    for rid, (prompt, max_new, arrival) in enumerate(workload[:6]):
        toks = rep.completed[rid]
        print(f"  {rid:3d} {arrival:7.1f} {len(prompt):2d} {max_new:7d}  "
              f"{list(map(int, toks[:8]))}")

    # spot-check against the reference oracle
    for rid in (0, args.requests // 2, args.requests - 1):
        prompt, max_new, _ = workload[rid]
        ref = np.asarray(greedy_generate(params, cfg, rules,
                                         np.asarray(prompt)[None],
                                         max_new=max_new))[0]
        assert np.array_equal(ref, rep.completed[rid]), rid
    print("  oracle spot-check: token-identical")

    # --- the same workload on the paged KV plane -------------------------
    paged_eng = ServingEngine(
        PagedTransformerModel(params, cfg, rules),
        EngineConfig(n_slots=args.slots, max_prompt_len=24, max_new_cap=12,
                     cache_len=36, page_size=4))
    for prompt, max_new, arrival in workload:
        paged_eng.submit(prompt, max_new, arrival=arrival)
    paged_rep = paged_eng.run()
    identical = all(np.array_equal(rep.completed[rid],
                                   paged_rep.completed[rid])
                    for rid in rep.completed)
    frag = {rid: pages for rid, pages
            in sorted(paged_eng.pool.page_history.items())
            if any(b != a + 1 for a, b in zip(pages, pages[1:]))}
    print(f"\npaged KV plane (page_size=4, "
          f"{paged_eng.pool.n_pages} pages):")
    print(f"  token-identical to the slot plane: {identical}")
    print(f"  page occupancy {paged_rep.page_occupancy:.2f}, "
          f"{len(frag)}/{args.requests} requests spanned "
          f"non-contiguous pages")
    for rid, pages in list(frag.items())[:3]:
        print(f"    rid {rid}: physical pages {list(pages)}")
    assert identical

    # --- capacity planning across heterogeneous replicas -----------------
    rates = [140.0, 90.0, 210.0, 60.0]   # measured tokens/sec per replica
    planner = CapacityPlanner(rates, mode="PCCS")
    plan = planner.plan(64)
    print(f"\ncapacity planner (PCCS) over replicas {rates}:")
    print(f"  shares: {plan.shares.tolist()}  (64 requests)")
    ft = planner.finish_times(plan)
    print(f"  per-replica finish (model units): "
          f"{np.round(ft, 1).tolist()}  spread {ft.max() - ft.min():.1f}")
    routed = planner.route(plan)
    print(f"  first 16 routed: {routed[:16].tolist()}")
    new_plan = planner.observe([140.0, 90.0, 140.0, 60.0], 64)
    print(f"  drift re-plan (replica 2 slowed): "
          f"{new_plan.shares.tolist() if new_plan else 'kept old plan'}")

    # memory-honest split: the fastest replica has the smallest page pool
    paged_planner = CapacityPlanner(rates, mode="PCCS",
                                    pages=[512, 512, 64, 512])
    pplan = paged_planner.plan_paged(64, pages_per_request=8)
    print(f"  page-capped shares (replica 2: 64 pages @ 8/request): "
          f"{pplan.shares.tolist()}  saturated={pplan.saturated.tolist()}")


if __name__ == "__main__":
    main()
