"""One planning API, three platforms (deliverable: the repro.plan story).

The same ``plan(topology, load)`` call splits a divisible load over
1. a flat heterogeneous star (a single TPU pod with a straggler),
2. the paper's §5 mesh (LP-based solvers as planning backends),
3. the production two-level multi-pod hierarchy — where the flat model's
   "every device has a private DCN link" assumption is priced against the
   shared-trunk truth.

    PYTHONPATH=src python examples/plan_topologies.py
"""

import numpy as np

from repro.core.network import random_mesh
from repro.plan import (HierarchicalTopology, MeshTopology, StarTopology,
                        compare_flat_hierarchical, plan,
                        production_topology)

# --- 1. flat star: one pod, one straggler ---------------------------------
speeds = np.array([1.0] * 7 + [0.4])           # device 7 thermally throttled
pp = plan(StarTopology.from_speeds(speeds), 4096, quantum=128,
          objective="PCSS")
print("flat star   :", pp.solver, "k =", pp.k,
      f" finish {pp.finish_time:.1f}")

# --- 2. mesh: the §5 LP family as a planning backend ----------------------
mesh = MeshTopology.from_network(random_mesh(3, 3, seed=1))
pm = plan(mesh, 200, objective="heuristic")
print("mesh        :", pm.solver, "k =", pm.k,
      f" finish {pm.finish_time:.1f}  ({pm.meta['lp_solves']} LP solves)")

# --- 3. two-level multi-pod: 2 x (16x16) behind DCN trunks ----------------
topo = production_topology(multi_pod=True, seed=0)
cmp = compare_flat_hierarchical(topo, 2048, objective="PCCS")
hier = cmp["hierarchical"]
print(f"hierarchical: {hier.solver}  pod shares {hier.meta['pod_shares']}"
      f"  finish {hier.finish_time:.1f}")
print(f"  vs flat star priced on the true trunks: "
      f"finish {cmp['flat_finish_on_topology']:.1f} "
      f"({cmp['finish_speedup']:.2f}x slower), "
      f"DCN volume -{cmp['dcn_reduction'] * 100:.1f}%")

# every consumer sees the same IR: the serving planner on a pod-spanning
# replica fleet is the identical call path
from repro.serve import CapacityPlanner

fleet = HierarchicalTopology.from_pod_speeds([[100.0, 120.0], [80.0, 95.0]])
planner = CapacityPlanner(topology=fleet, mode="PCCS")
rp = planner.plan(48)
print("serving     :", rp.partition.solver, "shares =", rp.shares,
      "->", planner.route(rp)[:12], "...")
